"""COO → BSR (block-sparse row) packing for the TPU SpMM kernel.

TPU-native sparse adjacency: 128×128 tiles, nonzero tiles packed dense and
streamed through the MXU (see kernels/bsr_spmm.py). After xDGP
repartitioning + relocation, nonzero tiles concentrate near the diagonal —
fewer tiles ⇒ proportionally less compute/DMA, which is how partition
quality becomes kernel speedup on TPU (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.structure import Graph

_INT32_MAX = np.iinfo(np.int32).max


def check_int32_index(value: int, what: str) -> int:
    """Fail-loud overflow guard for indices stored in int32 containers
    (``row_ptr``, ``block_cols``, ``nnzb``).  At the 10M-vertex tier these
    quantities approach 2^31; silently wrapping would corrupt the packing,
    so any consumer that is about to stuff ``value`` into an int32 slot
    calls this first (DESIGN.md §14 overflow policy)."""
    value = int(value)
    if value > _INT32_MAX:
        raise OverflowError(
            f"{what} = {value} overflows int32 (max {_INT32_MAX}); "
            f"the BSR packing stores this in an int32 container — shrink "
            f"the graph or raise the block size")
    return value


class BSRMatrix(NamedTuple):
    """Padded BSR. n_rows = n_cols = n_blocks * blk.

    blocks:     (nnzb_cap, blk, blk) packed nonzero tiles (float32/bf16)
    block_cols: (nnzb_cap,) tile-column index per packed tile (-1 padding)
    row_ptr:    (n_blocks + 1,) tile-row offsets into the packed arrays
    nnzb:       () live tile count
    """

    blocks: jax.Array
    block_cols: jax.Array
    row_ptr: jax.Array
    nnzb: jax.Array

    @property
    def blk(self) -> int:
        return self.blocks.shape[1]

    @property
    def n_blocks(self) -> int:
        return self.row_ptr.shape[0] - 1


def graph_to_bsr(graph: Graph, blk: int = 128, normalize: Optional[str] = None,
                 nnzb_cap: Optional[int] = None, dtype=np.float32) -> BSRMatrix:
    """Pack the symmetrised adjacency into BSR tiles.

    normalize: None -> A; "sym" -> D^-1/2 A D^-1/2; "row" -> D^-1 A.
    """
    n_cap = graph.n_cap
    n_pad = -(-n_cap // blk) * blk
    em = np.asarray(graph.edge_mask)
    s = np.asarray(graph.src)[em].astype(np.int64)
    d = np.asarray(graph.dst)[em].astype(np.int64)
    rows = np.concatenate([s, d])
    cols = np.concatenate([d, s])
    vals = np.ones(rows.shape[0], dtype=np.float64)
    if normalize is not None:
        deg = np.bincount(rows, minlength=n_pad).astype(np.float64)
        deg = np.maximum(deg, 1.0)
        if normalize == "sym":
            vals = vals / np.sqrt(deg[rows] * deg[cols])
        elif normalize == "row":
            vals = vals / deg[rows]
        else:
            raise ValueError(normalize)
    br, bc = rows // blk, cols // blk
    key = br * (n_pad // blk) + bc
    uniq, tile_of = np.unique(key, return_inverse=True)
    nnzb = uniq.shape[0]
    # row_ptr/block_cols/nnzb live in int32 containers: guard before packing
    check_int32_index(n_pad // blk, "n_blocks (tile rows)")
    check_int32_index(nnzb, "nnzb (nonzero tile count)")
    cap = int(nnzb_cap if nnzb_cap is not None else max(nnzb, 1))
    if cap < nnzb:
        raise ValueError(f"nnzb_cap {cap} < required {nnzb}")
    blocks = np.zeros((cap, blk, blk), dtype=dtype)
    block_cols = np.full((cap,), -1, dtype=np.int32)
    n_blocks = n_pad // blk
    row_counts = np.zeros(n_blocks, dtype=np.int64)
    tile_row = (uniq // n_blocks).astype(np.int64)
    tile_col = (uniq % n_blocks).astype(np.int64)
    block_cols[:nnzb] = tile_col
    np.add.at(row_counts, tile_row, 1)
    row_ptr = np.zeros(n_blocks + 1, dtype=np.int32)
    np.cumsum(row_counts, out=row_ptr[1:])
    # scatter all entries into their tiles with one flattened accumulate:
    # flat index = tile * blk² + (row within tile) * blk + (col within tile)
    flat = tile_of * (blk * blk) + (rows % blk) * blk + (cols % blk)
    np.add.at(blocks.reshape(-1), flat, vals)
    return BSRMatrix(blocks=jnp.asarray(blocks), block_cols=jnp.asarray(block_cols),
                     row_ptr=jnp.asarray(row_ptr), nnzb=jnp.asarray(nnzb, jnp.int32))


def bsr_density_stats(bsr: BSRMatrix) -> dict:
    """Diagnostics: how concentrated are the tiles (post-partitioning metric)."""
    nb = int(bsr.nnzb)
    cols = np.asarray(bsr.block_cols[:nb])
    rp = np.asarray(bsr.row_ptr)
    rows = np.repeat(np.arange(bsr.n_blocks), np.diff(rp))
    if nb == 0:
        return {"nnzb": 0, "diag_frac": 1.0, "mean_band": 0.0,
                "tiles_per_row": 0.0}
    diag = float(np.mean(rows == cols[: rows.shape[0]]))
    band = float(np.mean(np.abs(rows - cols[: rows.shape[0]])))
    return {"nnzb": nb, "diag_frac": diag, "mean_band": band,
            "tiles_per_row": nb / max(bsr.n_blocks, 1)}
