"""Graph data structures.

Static-shape (padded + masked) COO graph representation so the whole adaptive
partitioning loop and the GNN runtime stay jit-compatible while the topology
evolves (paper §4.1: change queue applied between supersteps).

Conventions
-----------
* ``src``/``dst`` are int32 arrays of length ``e_cap``; invalid (padding) slots
  hold ``-1`` in both endpoints and are excluded by ``edge_mask``.
* ``node_mask`` marks live vertices out of ``n_cap`` slots.
* Graphs are **undirected** for partitioning purposes (the paper's cut metric);
  we store each undirected edge once and symmetrise on demand.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded COO graph. All fields are device arrays; shapes are static."""

    src: jax.Array            # (e_cap,) int32, -1 = padding
    dst: jax.Array            # (e_cap,) int32
    node_mask: jax.Array      # (n_cap,) bool
    edge_mask: jax.Array      # (e_cap,) bool

    @property
    def n_cap(self) -> int:
        return self.node_mask.shape[0]

    @property
    def e_cap(self) -> int:
        return self.src.shape[0]

    @property
    def num_nodes(self) -> jax.Array:
        return jnp.sum(self.node_mask.astype(jnp.int32))

    @property
    def num_edges(self) -> jax.Array:
        return jnp.sum(self.edge_mask.astype(jnp.int32))

    def degrees(self) -> jax.Array:
        """Undirected degree per node slot (padding slots get 0)."""
        ones = self.edge_mask.astype(jnp.int32)
        d = jax.ops.segment_sum(ones, jnp.where(self.edge_mask, self.src, self.n_cap),
                                num_segments=self.n_cap + 1)[: self.n_cap]
        d = d + jax.ops.segment_sum(ones, jnp.where(self.edge_mask, self.dst, self.n_cap),
                                    num_segments=self.n_cap + 1)[: self.n_cap]
        return d

    def symmetrized(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Both edge directions: returns (src2, dst2, mask2) of length 2*e_cap."""
        s = jnp.concatenate([self.src, self.dst])
        d = jnp.concatenate([self.dst, self.src])
        m = jnp.concatenate([self.edge_mask, self.edge_mask])
        return s, d, m


def from_edges(src: np.ndarray, dst: np.ndarray, num_nodes: int,
               n_cap: Optional[int] = None, e_cap: Optional[int] = None) -> Graph:
    """Build a padded Graph from host edge arrays (deduplicated, no self loops)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    uniq = np.unique(lo * np.int64(num_nodes) + hi)
    lo = (uniq // num_nodes).astype(np.int32)
    hi = (uniq % num_nodes).astype(np.int32)
    e = lo.shape[0]
    n_cap = int(n_cap if n_cap is not None else num_nodes)
    e_cap = int(e_cap if e_cap is not None else e)
    if n_cap < num_nodes or e_cap < e:
        raise ValueError(f"capacity too small: n_cap={n_cap}<{num_nodes} or e_cap={e_cap}<{e}")
    s = np.full((e_cap,), -1, dtype=np.int32)
    d = np.full((e_cap,), -1, dtype=np.int32)
    s[:e], d[:e] = lo, hi
    nm = np.zeros((n_cap,), dtype=bool)
    nm[:num_nodes] = True
    em = np.zeros((e_cap,), dtype=bool)
    em[:e] = True
    return Graph(src=jnp.asarray(s), dst=jnp.asarray(d),
                 node_mask=jnp.asarray(nm), edge_mask=jnp.asarray(em))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A batch of topology changes (paper's change queue), padded/masked.

    Additions come as edge endpoint pairs; endpoints outside the current
    node set implicitly add vertices. Removals are edge-slot indices and
    node ids (removing a node drops all incident edges).
    """

    add_src: jax.Array        # (a_cap,) int32, -1 padding
    add_dst: jax.Array        # (a_cap,) int32
    add_mask: jax.Array       # (a_cap,) bool
    del_nodes: jax.Array      # (d_cap,) int32, -1 padding
    del_mask: jax.Array       # (d_cap,) bool

    @staticmethod
    def empty(a_cap: int = 0, d_cap: int = 0) -> "GraphDelta":
        return GraphDelta(
            add_src=jnp.full((a_cap,), -1, jnp.int32),
            add_dst=jnp.full((a_cap,), -1, jnp.int32),
            add_mask=jnp.zeros((a_cap,), bool),
            del_nodes=jnp.full((d_cap,), -1, jnp.int32),
            del_mask=jnp.zeros((d_cap,), bool),
        )


@jax.jit
def apply_delta(graph: Graph, delta: GraphDelta) -> Graph:
    """Apply a GraphDelta in-place (masked scatter); static shapes throughout.

    Edge additions fill the first free padding slots (prefix-sum allocation).
    Node deletions clear node_mask and mask out incident edges.
    """
    n_cap, e_cap = graph.n_cap, graph.e_cap

    # --- node deletions -------------------------------------------------
    # max-scatter, not set: padding slots alias index 0 and a plain set would
    # race a real deletion of node 0 with their False writes
    del_onehot = jnp.zeros((n_cap,), bool)
    del_ids = jnp.where(delta.del_mask, delta.del_nodes, 0)
    del_onehot = del_onehot.at[del_ids].max(delta.del_mask, mode="drop")
    node_mask = graph.node_mask & ~del_onehot

    # incident edges die with their nodes
    e_alive = graph.edge_mask
    e_alive = e_alive & ~del_onehot[jnp.clip(graph.src, 0, n_cap - 1)]
    e_alive = e_alive & ~del_onehot[jnp.clip(graph.dst, 0, n_cap - 1)]

    # --- node additions (implicit via edge endpoints) --------------------
    add_ids = jnp.concatenate([
        jnp.where(delta.add_mask, delta.add_src, 0),
        jnp.where(delta.add_mask, delta.add_dst, 0),
    ])
    add_flags = jnp.concatenate([delta.add_mask, delta.add_mask])
    node_mask = node_mask.at[add_ids].max(add_flags, mode="drop")

    # --- edge additions into free slots ----------------------------------
    a_cap = delta.add_mask.shape[0]
    if a_cap == 0:      # static shape: a zero-capacity delta adds nothing
        return Graph(src=jnp.where(e_alive, graph.src, -1),
                     dst=jnp.where(e_alive, graph.dst, -1),
                     node_mask=node_mask, edge_mask=e_alive)
    free = ~e_alive                                      # (e_cap,) free slots
    # the r-th valid addition goes into the r-th free slot
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1   # rank of slot s
    add_rank = jnp.cumsum(delta.add_mask.astype(jnp.int32)) - 1
    n_valid = jnp.sum(delta.add_mask.astype(jnp.int32))
    # rank r -> index of the r-th valid addition in the delta arrays
    add_idx_of_rank = jnp.full((a_cap,), -1, jnp.int32)
    add_idx_of_rank = add_idx_of_rank.at[
        jnp.where(delta.add_mask, add_rank, a_cap)].set(
        jnp.arange(a_cap, dtype=jnp.int32), mode="drop")
    hosts = free & (free_rank < n_valid)                 # slot receives an add
    cand = add_idx_of_rank[jnp.clip(free_rank, 0, a_cap - 1)]
    has_new = hosts & (cand >= 0)
    csafe = jnp.clip(cand, 0, a_cap - 1)
    new_src = jnp.where(has_new, delta.add_src[csafe],
                        jnp.where(e_alive, graph.src, -1))
    new_dst = jnp.where(has_new, delta.add_dst[csafe],
                        jnp.where(e_alive, graph.dst, -1))
    edge_mask = e_alive | has_new
    return Graph(src=new_src, dst=new_dst, node_mask=node_mask, edge_mask=edge_mask)


def to_csr(graph: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side CSR over the *symmetrised* live edges (for sampling etc.)."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    em = np.asarray(graph.edge_mask)
    s, d = src[em], dst[em]
    s2 = np.concatenate([s, d])
    d2 = np.concatenate([d, s])
    order = np.argsort(s2, kind="stable")
    s2, d2 = s2[order], d2[order]
    n = graph.n_cap
    counts = np.bincount(s2, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, d2.astype(np.int32)


def cut_edges(graph: Graph, assignment: jax.Array) -> jax.Array:
    """Number of live edges whose endpoints sit in different partitions."""
    a = assignment[jnp.clip(graph.src, 0, graph.n_cap - 1)]
    b = assignment[jnp.clip(graph.dst, 0, graph.n_cap - 1)]
    return jnp.sum((a != b) & graph.edge_mask)


def cut_ratio(graph: Graph, assignment: jax.Array) -> jax.Array:
    """Paper's quality metric: |E_c| / |E| over live edges."""
    e = jnp.maximum(graph.num_edges, 1)
    return cut_edges(graph, assignment) / e
