"""Dynamic-graph machinery: change queue + sliding-window streams (paper §4.1, §5.3).

``ChangeQueue`` buffers external topology mutations and releases them as
padded ``GraphDelta`` batches between supersteps — the paper's external API
("topology change requests are added to a change queue, and are processed at
the end of every iteration, or potentially after n iterations").

``SlidingWindowGraph`` replays a timestamped interaction stream (the CDR use
case): new events add edges; edges idle longer than the window are removed,
with their endpoints when orphaned.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Iterator, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structure import Graph, GraphDelta, apply_delta


class ChangeQueue:
    """Host-side buffer of pending topology changes with priorities."""

    def __init__(self, a_cap: int = 4096, d_cap: int = 1024):
        self.a_cap = a_cap
        self.d_cap = d_cap
        self._adds: Deque[Tuple[int, int]] = deque()
        self._dels: Deque[int] = deque()

    def add_edge(self, u: int, v: int) -> None:
        self._adds.append((u, v))

    def remove_node(self, v: int) -> None:
        self._dels.append(v)

    def __len__(self) -> int:
        return len(self._adds) + len(self._dels)

    def drain(self) -> GraphDelta:
        """Pop up to capacity changes into one padded GraphDelta."""
        a = min(len(self._adds), self.a_cap)
        d = min(len(self._dels), self.d_cap)
        add_src = np.full((self.a_cap,), -1, np.int32)
        add_dst = np.full((self.a_cap,), -1, np.int32)
        add_mask = np.zeros((self.a_cap,), bool)
        for i in range(a):
            u, v = self._adds.popleft()
            add_src[i], add_dst[i] = u, v
            add_mask[i] = True
        del_nodes = np.full((self.d_cap,), -1, np.int32)
        del_mask = np.zeros((self.d_cap,), bool)
        for i in range(d):
            del_nodes[i] = self._dels.popleft()
            del_mask[i] = True
        return GraphDelta(add_src=jnp.asarray(add_src), add_dst=jnp.asarray(add_dst),
                          add_mask=jnp.asarray(add_mask),
                          del_nodes=jnp.asarray(del_nodes),
                          del_mask=jnp.asarray(del_mask))


class SlidingWindowGraph:
    """CDR-style dynamic graph: stream of (t, u, v) events with expiry window.

    Mirrors the paper's mobile-network use case: "new calls add nodes and
    [edges] ... both are removed from the graph if they are inactive for more
    than the window length".
    """

    def __init__(self, graph: Graph, window: int, a_cap: int = 8192,
                 d_cap: int = 4096):
        self.graph = graph
        self.window = window
        self.a_cap = a_cap
        self.d_cap = d_cap
        self.last_seen: dict = {}            # node -> last active time

    def advance(self, events: np.ndarray, now: int) -> Graph:
        """Apply a batch of events (rows: t,u,v) and expire stale nodes."""
        queue = ChangeQueue(self.a_cap, self.d_cap)
        for t, u, v in events:
            queue.add_edge(int(u), int(v))
            self.last_seen[int(u)] = int(t)
            self.last_seen[int(v)] = int(t)
        horizon = now - self.window
        stale = [n for n, t in self.last_seen.items() if t < horizon]
        for n in stale:
            queue.remove_node(n)
            del self.last_seen[n]
        self.graph = apply_delta(self.graph, queue.drain())
        return self.graph


def stream_batches(times: np.ndarray, src: np.ndarray, dst: np.ndarray,
                   batch_span: int) -> Iterator[Tuple[int, np.ndarray]]:
    """Group a timestamped stream into time-span batches (speed-up factor
    is modelled by choosing a larger span per superstep)."""
    t0 = int(times.min()) if times.size else 0
    t_end = int(times.max()) if times.size else 0
    lo = t0
    while lo <= t_end:
        hi = lo + batch_span
        sel = (times >= lo) & (times < hi)
        rows = np.stack([times[sel], src[sel], dst[sel]], axis=1)
        yield hi, rows
        lo = hi
