"""Dynamic-graph compat layer (paper §4.1, §5.3) over ``repro.stream``.

``ChangeQueue`` and ``SlidingWindowGraph`` keep the seed API — external
topology mutations buffered between supersteps, CDR-style windowed replay —
but are now thin wrappers over the vectorized streaming layer in
``repro/stream/ingest.py`` (the per-event Python loops are gone; a drain is
array slicing, window expiry is a scatter-max plus one masked scan).

New code should use ``repro.stream.StreamEngine`` directly: it adds online
placement of arriving vertices, incremental cut tracking, and backpressure
accounting on top of this ingestion path.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.graph.structure import Graph, GraphDelta, apply_delta
from repro.stream.ingest import (EdgeStreamBuffer, WindowIngestor,
                                 stream_batches)

__all__ = ["ChangeQueue", "SlidingWindowGraph", "stream_batches"]


class ChangeQueue(EdgeStreamBuffer):
    """Host-side buffer of pending topology changes (seed-compatible API)."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "ChangeQueue is deprecated; push batches into "
            "repro.stream.EdgeStreamBuffer directly, or drive the full loop "
            "via repro.api.DynamicGraphSystem.step",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)

    def add_edge(self, u: int, v: int) -> None:
        self.push_edges(np.asarray([u]), np.asarray([v]))

    def remove_node(self, v: int) -> None:
        self.push_node_removals(np.asarray([v]))

    def drain(self) -> GraphDelta:
        """Pop up to capacity changes into one padded GraphDelta."""
        delta, _ = EdgeStreamBuffer.drain(self)
        return delta


class SlidingWindowGraph:
    """CDR-style dynamic graph: stream of (t, u, v) events with expiry window.

    Mirrors the paper's mobile-network use case: "new calls add nodes and
    [edges] ... both are removed from the graph if they are inactive for more
    than the window length". ``carry_backlog=False`` matches the seed
    semantics (per-batch overflow beyond the caps is dropped).
    """

    def __init__(self, graph: Graph, window: int, a_cap: int = 8192,
                 d_cap: int = 4096):
        warnings.warn(
            "SlidingWindowGraph is deprecated; use "
            "repro.api.DynamicGraphSystem (step/run) — it adds online "
            "placement, adaptation and incremental quality tracking on the "
            "same windowed-ingest path",
            DeprecationWarning, stacklevel=2)
        self.graph = graph
        self.window = window
        self.a_cap = a_cap
        self.d_cap = d_cap
        self._ingestor = WindowIngestor(n_cap=graph.n_cap, window=window,
                                        a_cap=a_cap, d_cap=d_cap,
                                        carry_backlog=False)

    @property
    def last_seen(self) -> dict:
        """Seed-compatible view of the tracker: {node: last active time}."""
        ls = self._ingestor.tracker.last_seen
        live = ls != self._ingestor.tracker.NEVER
        return {int(n): int(ls[n]) for n in np.flatnonzero(live)}

    def advance(self, events: np.ndarray, now: int) -> Graph:
        """Apply a batch of events (rows: t,u,v) and expire stale nodes."""
        delta, _ = self._ingestor.ingest(np.asarray(events), now)
        self.graph = apply_delta(self.graph, delta)
        return self.graph
